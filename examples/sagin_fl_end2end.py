"""End-to-end driver (deliverable b): federated training of the paper's
MNIST CNN over a Walker-Star constellation for a few hundred rounds,
comparing the adaptive scheme against the no-offloading baseline.

    PYTHONPATH=src python examples/sagin_fl_end2end.py [--rounds N]

Reduced defaults keep CPU runtime reasonable; raise --rounds/--devices and
--fraction for the paper-scale experiment.

Scenario registry
-----------------
Pass ``--scenario <name>`` to run against a named preset from
``repro.scenarios`` instead of the bare paper constellation — e.g.

    PYTHONPATH=src python examples/sagin_fl_end2end.py \
        --scenario degraded_links --rounds 50

selects the paper topology under ISL fades + weather, ``device_churn``
adds unreliable ground devices, ``mega_constellation`` swaps in a
1080-satellite shell, and ``multi_region`` spans four continents over a
shared constellation.  ``--list-scenarios`` prints every registered
preset.  Wall-clock/latency axes then reflect the *realized*
(dynamics-priced) round latencies, not just the analytic plan.

Multi-region modes
------------------
``--all-regions`` trains one INDEPENDENT model per region (the PR-2
behavior).  ``--global-model`` instead event-steps every region through
``SAGINEngine`` and merges the region models into ONE global model over
the inter-satellite links under a pluggable federation policy
(``repro.fl.federation``): ``--policy`` selects ``synchronous`` barrier
merges, FedMeld-style ``soft_async`` dispersal, ``partial``
quorum merges under ISL outages, or ``elected_hub`` aggregation;
``--merge-every N`` overrides the cadence (0 disables merging).
Example:

    PYTHONPATH=src python examples/sagin_fl_end2end.py \
        --scenario multi_region --global-model --rounds 20 \
        --policy soft_async

Observability
-------------
``--trace PATH`` records the run with ``repro.obs``: a ``repro-trace/1``
JSONL file plus a Perfetto sibling (``PATH`` with ``.perfetto.json``)
that renders one timeline track per region in https://ui.perfetto.dev.
Summarize with ``python -m repro.obs report PATH``.  Pair with
``--execution batched`` to also capture per-bucket dispatch spans.
"""
import argparse
import dataclasses

from repro.fl import FLConfig, run_fl
from repro.scenarios import get_scenario, list_scenarios


def summarize(tag, res, rounds):
    best = max(res.accuracies)
    tta = res.time_to_accuracy(0.8)
    print(f"[{tag:>14s}] {rounds} rounds | "
          f"training time {res.times[-1]:9.0f} s | "
          f"best acc {best:.3f} | "
          f"time-to-80% {'%.0f s' % tta if tta else 'not reached'}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--devices", type=int, default=10)
    ap.add_argument("--air", type=int, default=2)
    ap.add_argument("--fraction", type=float, default=0.02)
    ap.add_argument("--dataset", default="mnist")
    ap.add_argument("--noniid", action="store_true")
    ap.add_argument("--constellation", action="store_true",
                    help="drive coverage windows from Walker-Star geometry")
    ap.add_argument("--scenario", default=None,
                    help="named preset from repro.scenarios "
                         "(see --list-scenarios)")
    ap.add_argument("--all-regions", action="store_true",
                    help="with a multi-region scenario: train one "
                         "INDEPENDENT FL model per region over the shared "
                         "constellation")
    ap.add_argument("--global-model", action="store_true",
                    help="with a multi-region scenario: merge region "
                         "models into ONE global model over the ISLs at "
                         "the scenario's merge cadence")
    ap.add_argument("--merge-every", type=int, default=None,
                    help="override the scenario's merge cadence in rounds "
                         "(0 disables merging)")
    ap.add_argument("--policy", default=None,
                    help="federation policy for --global-model: "
                         "synchronous | soft_async | partial | elected_hub "
                         "(default: the scenario's; see "
                         "repro.fl.federation)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a repro.obs trace (JSONL + Perfetto "
                         "sibling) of the run to PATH; inspect with "
                         "`python -m repro.obs report PATH`")
    ap.add_argument("--execution", default="auto",
                    choices=["auto", "batched", "sequential"],
                    help="round execution mode (FLConfig.execution); "
                         "batched emits bucket_dispatch trace spans")
    ap.add_argument("--cohort-sharding", default="auto",
                    choices=["auto", "mesh", "off"],
                    help="mesh-shard the batched engine's bucket client "
                         "axis over visible devices "
                         "(FLConfig.cohort_sharding); force multiple CPU "
                         "devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--list-scenarios", action="store_true")
    args = ap.parse_args()

    if args.list_scenarios:
        for name in list_scenarios():
            print(f"{name:>20s}  {get_scenario(name).description}")
        return

    common = dict(dataset=args.dataset, iid=not args.noniid,
                  n_rounds=args.rounds, n_devices=args.devices,
                  n_air=args.air, train_fraction=args.fraction,
                  h_local=3, eval_size=1024,
                  use_constellation=args.constellation,
                  scenario=args.scenario, execution=args.execution,
                  cohort_sharding=args.cohort_sharding, obs=args.trace)

    if args.scenario and args.global_model:
        import math

        from repro.fl.federation import FederationConfig
        from repro.sim import SAGINEngine
        scn = get_scenario(args.scenario)
        if args.merge_every is not None or args.policy:
            fed = scn.resolved_federation() or FederationConfig(every=2)
            if args.merge_every is not None:
                fed = (None if args.merge_every == 0 else
                       dataclasses.replace(fed, every=args.merge_every))
            if args.policy and fed is not None:
                fed = dataclasses.replace(fed, policy=args.policy)
            # also null the deprecated merge_* fields: resolved_federation
            # would resurrect them when fed is None (--merge-every 0 on a
            # legacy scenario must really disable merging)
            scn = dataclasses.replace(scn, federation=fed,
                                      merge_every=None)
        eng = SAGINEngine(scn, fl=FLConfig(strategy="adaptive", **common))
        eng.run(args.rounds)
        for region, res in eng.fl_results.items():
            summarize(region, res, args.rounds)
        for m in eng.merges:
            accs = [a for a in m.accuracies if not math.isnan(a)]
            print(f"   {m.policy:>11s} merge @ round {m.barrier_round:>3d} "
                  f"t={m.time:9.0f} s"
                  f" | hub {m.hub} | {len(m.participants)} region(s)"
                  f" | max staleness {max(m.staleness):7.1f} s"
                  f" | isl cost {max(m.isl_costs):6.1f} s"
                  f" | global acc {max(accs):.3f}")
        if eng.global_params is None:
            print("   (merging disabled: independent per-region models)")
        return

    if args.scenario and args.all_regions:
        from repro.sim import run_fl_all_regions
        results = run_fl_all_regions(FLConfig(strategy="adaptive", **common),
                                     args.scenario)
        for region, res in results.items():
            summarize(region, res, args.rounds)
        return

    for strategy in ("adaptive", "none"):
        cfg = FLConfig(strategy=strategy, **common)
        if args.trace:
            # one trace per compared run (the flush is a full rewrite,
            # so sharing a path would keep only the last strategy)
            stem, dot, ext = args.trace.rpartition(".")
            per = (f"{stem}.{strategy}.{ext}" if dot
                   else f"{args.trace}.{strategy}")
            cfg = dataclasses.replace(cfg, obs=per)
        res = run_fl(cfg)
        summarize(strategy, res, args.rounds)
        if strategy == "adaptive":
            p = res.layer_portions[-1]
            print(f"            final placement ground/air/space: "
                  f"{p['ground']:.0%}/{p['air']:.0%}/{p['space']:.0%}; "
                  f"cases used: {sorted(set(res.cases))}")


if __name__ == "__main__":
    main()
