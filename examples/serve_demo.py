"""Batched serving demo: greedy decode with the production serve path.

Runs a reduced architecture through prefill (teacher-forced forward) and
then batched one-token decode steps against the same cache structure the
multi-pod `launch/serve.py` factory shards — i.e. the real serving code
path, minus the mesh.

    PYTHONPATH=src python examples/serve_demo.py --arch deepseek-v2-lite-16b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rng = np.random.default_rng(0)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    b, p_len = args.batch, args.prompt_len
    cache_len = p_len + args.gen

    if cfg.input_mode == "tokens":
        prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, p_len)),
                             jnp.int32)
        tok_at = lambda i: prompt[:, i:i + 1]
    else:
        prompt = jnp.asarray(rng.normal(size=(b, p_len, cfg.d_model)),
                             jnp.float32)
        tok_at = lambda i: prompt[:, i:i + 1, :]

    step = jax.jit(T.serve_step, static_argnums=1)
    cache = T.init_cache(cfg, b, cache_len)

    # prefill via repeated decode (the cache-consistency test guarantees
    # this equals the teacher-forced forward)
    t0 = time.time()
    logits = None
    for i in range(p_len):
        logits, cache = step(params, cfg, cache, tok_at(i), jnp.int32(i))
    print(f"[{args.arch}] prefilled {p_len} tokens in {time.time()-t0:.2f}s")

    # greedy generation
    out = []
    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.time()
    for i in range(p_len, cache_len):
        inp = tok if cfg.input_mode == "tokens" else jnp.zeros(
            (b, 1, cfg.d_model), jnp.float32)
        logits, cache = step(params, cfg, cache, inp, jnp.int32(i))
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(np.asarray(tok[:, 0]))
    dt = time.time() - t0
    gen = np.stack(out, 1)
    print(f"generated {args.gen} tokens x batch {b} in {dt:.2f}s "
          f"({args.gen * b / dt:.1f} tok/s on CPU)")
    print("sequences:")
    for r in range(b):
        print("  ", gen[r].tolist())


if __name__ == "__main__":
    main()
